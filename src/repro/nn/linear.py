"""QuantLinear / QuantConv — every matmul-bearing layer in the framework.

The paper's technique is a first-class mode of this layer:

* ``mode='none'`` — float weights (the floating-point baseline),
* ``mode='qat'``  — baseline quantization-aware training (paper Sec. 2.1):
  per-channel weight scales, per-tensor activation scales, z=0, half-way
  rounding, STE,
* ``mode='a2q'``  — accumulator-aware quantization (paper Sec. 4): l1
  weight-normalized reparameterization (v, t, d), norm cap from the target
  accumulator width P, round-toward-zero.  ``penalty()`` exposes the layer's
  regularizer term.

Hidden layers use (M, N, P) from :class:`~repro.configs.base.QuantConfig`;
layers flagged ``boundary=True`` (first/last) stay at 8-bit as in App. B.
``input_signed`` reflects the preceding nonlinearity (ReLU -> unsigned).

Deployment: ``deploy_linear`` converts a trained A2Q layer to (int8 weights,
per-channel scale) — the artifact whose l1 norm provably fits the P-bit
accumulator — used by the serve path and by the int8-weight-storage roofline
lever.

Integer-fast serving: with ``int_forward=True`` (``Runtime(int_forward=...)``
/ ``--int-forward``) a deployed layer skips the dequant + bf16 dot and runs
``act_quant(x) -> int8 @ int8 -> int32 -> scaled output`` through the fused
W8A8 kernel (``kernels/int_matmul.py``), with the int16 partial-sum spill
engaged automatically when the layer's A2Q ``acc_bits <= 16`` — the paper's
guarantee is exactly what makes both the integer accumulation and the narrow
carry safe on the serve path.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core.a2q import a2q_int_weights, a2q_norm_cap, apply_a2q, init_a2q
from repro.core.quantizers import (
    act_quant_int,
    apply_act_quant,
    apply_weight_qat,
    init_act_quant,
    init_weight_qat,
    weight_qat_int,
)
from repro.nn.module import Boxed, box, kaiming

__all__ = [
    "init_linear",
    "apply_linear",
    "linear_penalty",
    "deploy_linear",
    "init_conv",
    "apply_conv",
]


def _bits(cfg: QuantConfig, boundary: bool) -> tuple[int, int]:
    if boundary:
        return cfg.boundary_bits, cfg.boundary_bits
    return cfg.weight_bits, cfg.act_bits


def init_linear(
    key,
    d_in: int,
    d_out: int,
    cfg: QuantConfig,
    *,
    axes: Sequence[Optional[str]] = ("embed", "mlp"),
    use_bias: bool = False,
    boundary: bool = False,
    input_signed: bool = True,
    w_std: Optional[float] = None,
    act_absmax: float = 6.0,
) -> dict:
    """Weights are stored ``(d_in, d_out)`` — output channels (accumulators)
    on the last axis, matching ``core.a2q`` conventions."""
    k_w, _ = jax.random.split(key)
    if w_std is None:
        w = kaiming(k_w, (d_in, d_out), fan_in=d_in)
    else:
        w = jax.random.normal(k_w, (d_in, d_out)) * w_std
    M, N = _bits(cfg, boundary)
    out_axis = axes[-1]
    p: dict = {}
    if cfg.mode == "none":
        p["w"] = box(w, tuple(axes))
    elif cfg.mode == "qat":
        p["w"] = box(w, tuple(axes))
        wq = init_weight_qat(w, M)
        p["wq"] = {"log2_scale": box(wq["log2_scale"], (out_axis,))}
        aq = init_act_quant(N, input_signed, init_absmax=act_absmax)
        p["aq"] = {"log2_scale": box(aq["log2_scale"], ())}
    elif cfg.mode == "a2q":
        a = init_a2q(w, M, cfg.acc_bits, N, input_signed)
        p["v"] = box(a["v"], tuple(axes))
        p["t"] = box(a["t"], (out_axis,))
        p["d"] = box(a["d"], (out_axis,))
        aq = init_act_quant(N, input_signed, init_absmax=act_absmax)
        p["aq"] = {"log2_scale": box(aq["log2_scale"], ())}
    else:
        raise ValueError(cfg.mode)
    if use_bias:
        p["b"] = box(jnp.zeros((d_out,), jnp.float32), (out_axis,))
    return p


def _quant_weights(params: dict, cfg: QuantConfig, boundary: bool, input_signed: bool):
    M, N = _bits(cfg, boundary)
    if "q8" in params:  # deployed int8 storage (beyond-paper serve lever)
        return params["q8"].astype(jnp.float32) * params["s8"]
    if cfg.mode == "none":
        return params["w"]
    if cfg.mode == "qat":
        return apply_weight_qat({"log2_scale": params["wq"]["log2_scale"]}, params["w"], M)
    if cfg.mode == "a2q":
        return apply_a2q(
            {"v": params["v"], "t": params["t"], "d": params["d"]},
            M,
            cfg.acc_bits,
            N,
            input_signed,
        )
    raise ValueError(cfg.mode)


def _int_forward_applicable(params: dict, N: int, input_signed: bool) -> bool:
    """The fused W8A8 path needs deployed int8 storage, an activation
    quantizer to produce the int8 operand, an int8-representable act code
    range — signed ``N <= 8`` ([-128, 127]) or unsigned ``N <= 7`` ([0, 127];
    unsigned 8-bit codes reach 255 and would wrap the int8 operand, so e.g.
    the rwkv6 channel-mix ``wv`` after squared-relu stays on the dequant
    path) — and an unstacked (2D) weight: vmapped expert/layer stacks keep
    the dequant path (a ``pallas_call`` has no batching rule here)."""
    if "q8" not in params or "aq" not in params or params["q8"].ndim != 2:
        return False
    return N <= 8 if input_signed else N <= 7


def _apply_linear_int8(
    params: dict,
    x: jnp.ndarray,
    cfg: QuantConfig,
    *,
    boundary: bool,
    input_signed: bool,
    compute_dtype,
) -> jnp.ndarray:
    """Fused W8A8 forward: one ``pallas_call`` from int8 activations to the
    scaled output.  The activation scale folds into the per-channel weight
    scale, so the epilogue is a single per-column fp32 rescale (+ bias); the
    int16 partial-sum spill engages when A2Q guarantees ``acc_bits <= 16``.
    """
    from repro.kernels import ops

    M, N = _bits(cfg, boundary)
    xq, x_scale = act_quant_int(
        {"log2_scale": params["aq"]["log2_scale"]},
        x.astype(jnp.float32), N, signed=input_signed,
    )
    K = x.shape[-1]
    a2q = cfg.mode == "a2q"
    y = ops.int_matmul(
        xq.astype(jnp.int8).reshape(-1, K),
        params["q8"],
        acc_bits=cfg.acc_bits if a2q else 32,
        mode="exact",
        spill_int16=a2q and cfg.acc_bits <= 16,
        scale=x_scale * params["s8"].astype(jnp.float32),
        bias=params.get("b"),
    )
    return y.reshape(*x.shape[:-1], y.shape[-1]).astype(compute_dtype)


def apply_linear(
    params: dict,
    x: jnp.ndarray,
    cfg: QuantConfig,
    *,
    boundary: bool = False,
    input_signed: bool = True,
    compute_dtype=jnp.bfloat16,
    int_forward: bool = False,
) -> jnp.ndarray:
    """``y = act_quant(x) @ quant(w) (+ b)`` in ``compute_dtype``.

    ``int_forward=True`` on a deployed layer (``q8``/``s8`` present) runs the
    fused W8A8 integer path instead of dequant + ``compute_dtype`` dot.
    """
    M, N = _bits(cfg, boundary)
    if int_forward and _int_forward_applicable(params, N, input_signed):
        return _apply_linear_int8(
            params, x, cfg,
            boundary=boundary, input_signed=input_signed, compute_dtype=compute_dtype,
        )
    if cfg.mode != "none" and "aq" in params:
        x = apply_act_quant(
            {"log2_scale": params["aq"]["log2_scale"]}, x, N, signed=input_signed
        )
    w = _quant_weights(params, cfg, boundary, input_signed).astype(compute_dtype)
    y = jnp.dot(x.astype(compute_dtype), w)
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y


def linear_penalty(params: dict, cfg: QuantConfig, boundary: bool, input_signed: bool) -> jnp.ndarray:
    """This layer's ``R_l = sum_i max(t_i - T_i, 0)`` (zero unless a2q)."""
    if cfg.mode != "a2q" or "t" not in params:
        return jnp.zeros((), jnp.float32)
    _, N = _bits(cfg, boundary)
    T = a2q_norm_cap(params["d"], cfg.acc_bits, N, input_signed)
    return jnp.sum(jnp.maximum(params["t"] - T, 0.0))


def deploy_linear(params: dict, cfg: QuantConfig, *, boundary: bool = False, input_signed: bool = True) -> dict:
    """A2Q/QAT layer -> inference artifacts {q8 int8, s8 scale [, b, aq]}."""
    M, N = _bits(cfg, boundary)
    if cfg.mode == "a2q":
        q, s = a2q_int_weights(
            {"v": params["v"], "t": params["t"], "d": params["d"]},
            M,
            cfg.acc_bits,
            N,
            input_signed,
        )
    elif cfg.mode == "qat":
        q, s = weight_qat_int({"log2_scale": params["wq"]["log2_scale"]}, params["w"], M)
    else:
        raise ValueError("deploy requires a quantized mode")
    out = {"q8": q.astype(jnp.int8), "s8": s.astype(jnp.float32)}
    if "b" in params:
        out["b"] = params["b"]
    if "aq" in params:
        out["aq"] = params["aq"]
    return out


# ---------------------------------------------------------------------------
# Conv (vision benchmarks: MobileNetV1 / ResNet18 / ESPCN / UNet)
# ---------------------------------------------------------------------------


def init_conv(
    key,
    c_in: int,
    c_out: int,
    kernel: tuple[int, int],
    cfg: QuantConfig,
    *,
    groups: int = 1,
    use_bias: bool = False,
    boundary: bool = False,
    input_signed: bool = False,  # vision nets are ReLU nets -> unsigned acts
) -> dict:
    """HWIO weights ``(kh, kw, c_in/groups, c_out)`` — channel axis last, so
    A2Q's per-output-channel reduction (= per accumulator, K = kh*kw*c_in/g)
    applies unchanged."""
    kh, kw = kernel
    fan_in = kh * kw * (c_in // groups)
    w = kaiming(key, (kh, kw, c_in // groups, c_out), fan_in=fan_in)
    axes = (None, None, None, "conv_out")
    M, N = _bits(cfg, boundary)
    p: dict = {}
    if cfg.mode == "none":
        p["w"] = box(w, axes)
    elif cfg.mode == "qat":
        p["w"] = box(w, axes)
        p["wq"] = {"log2_scale": box(init_weight_qat(w, M)["log2_scale"], ("conv_out",))}
        p["aq"] = {"log2_scale": box(init_act_quant(N, input_signed)["log2_scale"], ())}
    elif cfg.mode == "a2q":
        a = init_a2q(w, M, cfg.acc_bits, N, input_signed)
        p["v"] = box(a["v"], axes)
        p["t"] = box(a["t"], ("conv_out",))
        p["d"] = box(a["d"], ("conv_out",))
        p["aq"] = {"log2_scale": box(init_act_quant(N, input_signed)["log2_scale"], ())}
    if use_bias:
        p["b"] = box(jnp.zeros((c_out,), jnp.float32), ("conv_out",))
    return p


def apply_conv(
    params: dict,
    x: jnp.ndarray,
    cfg: QuantConfig,
    *,
    stride: tuple[int, int] = (1, 1),
    padding: str = "SAME",
    groups: int = 1,
    boundary: bool = False,
    input_signed: bool = False,
    compute_dtype=jnp.float32,
) -> jnp.ndarray:
    """NHWC convolution with the same quant pipeline as apply_linear."""
    M, N = _bits(cfg, boundary)
    if cfg.mode != "none" and "aq" in params:
        x = apply_act_quant(
            {"log2_scale": params["aq"]["log2_scale"]}, x, N, signed=input_signed
        )
    w = _quant_weights(params, cfg, boundary, input_signed).astype(compute_dtype)
    y = jax.lax.conv_general_dilated(
        x.astype(compute_dtype),
        w,
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y
