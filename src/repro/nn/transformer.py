"""Blocks and scan-over-layers stacks.

A model is a sequence of *stacks*; each stack is ``count`` identical blocks
compiled as one ``jax.lax.scan`` over stacked parameters (HLO size and compile
time O(1) in depth — essential for compiling 61-layer deepseek-v3 against 512
host devices).  Heterogeneous architectures (deepseek dense-then-MoE, llama4
local/global interleave) are expressed as multiple stacks.

Block kinds:
  * ``attn_mlp`` — pre-norm GQA/MLA + SwiGLU (or parallel attn+FFN, command-r)
  * ``moe``      — pre-norm attention + MoE FFN (+ shared experts)
  * ``rwkv6``    — time-mix + channel-mix
  * ``hymba``    — parallel SWA-attention and mamba(SSD) heads, then MLP

Every block returns ``(x, cache, a2q_penalty)``; the scan accumulates the
penalty so ``L_reg`` falls out of the forward pass for free.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, QuantConfig, StackConfig
from repro.nn.attention import apply_attention, attention_penalty, init_attention, init_attn_cache
from repro.nn.linear import (
    IntAct,
    apply_linear,
    chain_out_aq,
    init_linear,
    linear_penalty,
)
from repro.nn.moe import apply_moe, init_moe, moe_penalty
from repro.nn.module import unbox, with_layers_axis
from repro.nn.norms import apply_norm, init_norm
from repro.nn.ssm import (
    apply_mamba_heads,
    apply_rwkv6_channelmix,
    apply_rwkv6_timemix,
    init_mamba_heads,
    init_rwkv6_channelmix,
    init_rwkv6_timemix,
)

__all__ = ["init_stack", "apply_stack", "init_stack_cache", "tree_a2q_penalty"]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def _init_mlp(key, d: int, ff: int, q: QuantConfig, gated: bool, use_bias: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": init_linear(ks[0], d, ff, q, axes=("embed", "mlp"), use_bias=use_bias),
        "w_out": init_linear(ks[1], ff, d, q, axes=("mlp", "embed"), use_bias=use_bias),
    }
    if gated:
        p["w_gate"] = init_linear(ks[2], d, ff, q, axes=("embed", "mlp"), use_bias=use_bias)
    return p


def _apply_mlp(p: dict, x, q: QuantConfig, compute_dtype,
               int_forward: bool = False, int_chain: bool = False) -> jnp.ndarray:
    lin = functools.partial(
        apply_linear, cfg=q, compute_dtype=compute_dtype,
        int_forward=int_forward, int_chain=int_chain,
    )
    if "w_gate" in p:
        # gated MLP: the silu(gate) * up product is a chain break (an fp
        # elementwise join of two linears), so every edge quantizes in its
        # own prologue — no int8 handoff exists here
        h = lin(p["w_in"], x=x, site="mlp.w_in")
        h = jax.nn.silu(
            lin(p["w_gate"], x=x, site="mlp.w_gate").astype(jnp.float32)
        ).astype(compute_dtype) * h
        return lin(p["w_out"], x=h, site="mlp.w_out")
    # non-gated MLP: w_in -> gelu -> w_out is a true producer/consumer chain;
    # w_in requantizes into w_out's quantizer in its epilogue (gelu replayed
    # in-register) and hands int8 codes across
    out_aq = (chain_out_aq(p["w_out"], q, act_fn="gelu") if int_chain else None)
    h = lin(p["w_in"], x=x, site="mlp.w_in", out_aq=out_aq)
    if not isinstance(h, IntAct):
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(compute_dtype)
    return lin(p["w_out"], x=h, site="mlp.w_out")


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _init_block(key, arch: ArchConfig, s: StackConfig) -> dict:
    d, q = arch.d_model, arch.quant
    ks = jax.random.split(key, 4)
    norm = lambda: init_norm(d, arch.norm)
    if s.kind in ("attn_mlp", "moe"):
        p = {"ln1": norm(), "attn": init_attention(ks[0], d, s.attn, q, arch.use_bias)}
        if not s.parallel_block:
            p["ln2"] = norm()
        if s.kind == "attn_mlp":
            p["mlp"] = _init_mlp(ks[1], d, s.d_ff, q, s.mlp_gated, arch.use_bias)
        else:
            p["moe"] = init_moe(ks[1], d, s.moe, q)
        return p
    if s.kind == "rwkv6":
        return {
            "ln1": norm(),
            "tm": init_rwkv6_timemix(ks[0], d, s.ssm, q),
            "ln2": norm(),
            "cm": init_rwkv6_channelmix(ks[1], d, s.d_ff, q),
        }
    if s.kind == "hymba":
        return {
            "ln1": norm(),
            "attn": init_attention(ks[0], d, s.attn, q, arch.use_bias),
            "mamba": init_mamba_heads(ks[1], d, s.ssm, q),
            "ln2": norm(),
            "mlp": _init_mlp(ks[2], d, s.d_ff, q, s.mlp_gated, arch.use_bias),
        }
    raise ValueError(s.kind)


def _apply_block(
    p: dict,
    x: jnp.ndarray,
    arch: ArchConfig,
    s: StackConfig,
    positions: jnp.ndarray,
    cache: Optional[dict],
    *,
    mesh=None,
    ep_axis: Optional[str] = None,
    mla_absorb: bool = False,
    view: Optional[dict] = None,
    decode_kernel: bool = False,
    int_forward: bool = False,
    int_chain: bool = False,
):
    q = arch.quant
    cd = jnp.dtype(arch.compute_dtype)
    norm = functools.partial(apply_norm, kind=arch.norm, eps=arch.norm_eps)
    new_cache: dict = {}
    if s.kind in ("attn_mlp", "moe"):
        h = norm(p["ln1"], x)
        attn_out, c = apply_attention(
            p["attn"], h, s.attn, q, positions, (cache or {}).get("attn"),
            q_chunk=arch.attn_q_chunk, compute_dtype=cd, mla_absorb=mla_absorb,
            view=view, decode_kernel=decode_kernel, int_forward=int_forward,
            int_chain=int_chain,
        )
        if c is not None:
            new_cache["attn"] = c
        if s.parallel_block:
            if s.kind == "moe":
                ffn = apply_moe(p["moe"], h, s.moe, q, ep_axis=ep_axis, mesh=mesh,
                                compute_dtype=cd, int_forward=int_forward,
                                int_chain=int_chain)
            else:
                ffn = _apply_mlp(p["mlp"], h, q, cd, int_forward, int_chain)
            x = x + attn_out + ffn
        else:
            x = x + attn_out
            h2 = norm(p["ln2"], x)
            if s.kind == "moe":
                ffn = apply_moe(p["moe"], h2, s.moe, q, ep_axis=ep_axis, mesh=mesh,
                                compute_dtype=cd, int_forward=int_forward,
                                int_chain=int_chain)
            else:
                ffn = _apply_mlp(p["mlp"], h2, q, cd, int_forward, int_chain)
            x = x + ffn
    elif s.kind == "rwkv6":
        h = norm(p["ln1"], x)
        y, c = apply_rwkv6_timemix(p["tm"], h, s.ssm, q, (cache or {}).get("tm"), compute_dtype=cd, int_forward=int_forward, int_chain=int_chain)
        if c is not None:
            new_cache["tm"] = c
        x = x + y
        h2 = norm(p["ln2"], x)
        y2, c2 = apply_rwkv6_channelmix(p["cm"], h2, q, (cache or {}).get("cm"), compute_dtype=cd, int_forward=int_forward, int_chain=int_chain)
        if c2 is not None:
            new_cache["cm"] = c2
        x = x + y2
    elif s.kind == "hymba":
        h = norm(p["ln1"], x)
        attn_out, c = apply_attention(
            p["attn"], h, s.attn, q, positions, (cache or {}).get("attn"),
            q_chunk=arch.attn_q_chunk, compute_dtype=cd,
            view=view, decode_kernel=decode_kernel, int_forward=int_forward,
            int_chain=int_chain,
        )
        if c is not None:
            new_cache["attn"] = c
        m_out, cm = apply_mamba_heads(p["mamba"], h, s.ssm, q, (cache or {}).get("mamba"), compute_dtype=cd, int_forward=int_forward, int_chain=int_chain)
        if cm is not None:
            new_cache["mamba"] = cm
        x = x + 0.5 * (attn_out + m_out)
        x = x + _apply_mlp(p["mlp"], norm(p["ln2"], x), q, cd, int_forward, int_chain)
    else:
        raise ValueError(s.kind)

    penalty = tree_a2q_penalty(p, q)
    return x, (new_cache or None), penalty


# Param subtrees whose matmul consumes *unsigned* activations (post-relu^2):
_UNSIGNED_LEAF_NAMES = {"wv_channelmix"}


def tree_a2q_penalty(p, q: QuantConfig) -> jnp.ndarray:
    """Walk a block's params and sum every A2Q layer's regularizer.

    The channel-mix ``wv`` (post-relu^2, unsigned input) is the one layer whose
    cap uses 1_signed = 0; all other transformer matmuls see signed inputs.
    """
    total = jnp.zeros((), jnp.float32)
    if q.mode != "a2q":
        return total

    def walk(node, path):
        nonlocal total
        if isinstance(node, dict):
            if "t" in node and "d" in node and "v" in node:
                signed = not (len(path) >= 2 and path[-2] == "cm" and path[-1] == "wv")
                if node["t"].ndim == 2:  # stacked experts (E, C)
                    from repro.core.a2q import a2q_norm_cap

                    T = a2q_norm_cap(node["d"], q.acc_bits, q.act_bits, signed)
                    total = total + jnp.sum(jnp.maximum(node["t"] - T, 0.0))
                else:
                    total = total + linear_penalty(node, q, False, signed)
            else:
                for k, v in node.items():
                    walk(v, path + (k,))

    walk(p, ())
    return total


# ---------------------------------------------------------------------------
# Stacks: vmapped init, scanned apply
# ---------------------------------------------------------------------------


def init_stack(key, arch: ArchConfig, s: StackConfig):
    """Stacked (leading ``count`` dim) boxed params for one stack."""
    keys = jax.random.split(key, s.count)
    stacked = jax.vmap(lambda k: _init_block(k, arch, s))(keys)
    return with_layers_axis(stacked)


def apply_stack(
    params,
    x: jnp.ndarray,
    arch: ArchConfig,
    s: StackConfig,
    positions: jnp.ndarray,
    cache=None,
    *,
    mesh=None,
    ep_axis: Optional[str] = None,
    mla_absorb: bool = False,
    view: Optional[dict] = None,
    decode_kernel: bool = False,
    int_forward: bool = False,
    int_chain: bool = False,
):
    """Scan ``s.count`` blocks.  Returns (x, new_cache, total_penalty).

    ``view`` (the paged block-table, shared by every layer), ``decode_kernel``
    and ``int_forward``/``int_chain`` (the fused W8A8 serve path and its
    int8-out chaining) pass straight through to the attention / linear
    layers.  Chained activations never cross a block boundary (every block
    ends in a residual add — a chain break), so the scan carry stays fp.
    """

    def body(carry, layer_in):
        xc = carry
        layer_params, layer_cache = layer_in
        xn, new_cache, pen = _apply_block(
            layer_params, xc, arch, s, positions, layer_cache,
            mesh=mesh, ep_axis=ep_axis, mla_absorb=mla_absorb,
            view=view, decode_kernel=decode_kernel, int_forward=int_forward,
            int_chain=int_chain,
        )
        return xn, (new_cache, pen)

    if arch.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)

    if s.count == 1 or arch.unroll_stacks:
        # Python loop: singleton stacks, and the roofline costing variants
        # (XLA cost_analysis counts a scan body once, so per-layer costs are
        # measured on unrolled models — see launch/dryrun.py).
        new_caches, pens = [], []
        xc = x
        for i in range(s.count):
            lp = jax.tree.map(lambda a: a[i], params)
            lc = jax.tree.map(lambda a: a[i], cache) if cache is not None else None
            xc, (nc, pen) = body(xc, (lp, lc))
            new_caches.append(nc)
            pens.append(pen)
        if new_caches[0] is not None:
            new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *new_caches)
        else:
            new_cache = None
        return xc, new_cache, sum(pens)

    x, (new_cache, pens) = jax.lax.scan(body, x, (params, cache))
    return x, new_cache, jnp.sum(pens)


def init_stack_cache(arch: ArchConfig, s: StackConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Stacked decode cache for one stack (leading dim = s.count)."""
    d = arch.d_model

    def one():
        if s.kind in ("attn_mlp", "moe"):
            return {"attn": init_attn_cache(batch, s.attn, max_seq, dtype)}
        if s.kind == "rwkv6":
            H = d // s.ssm.head_dim
            return {
                "tm": {
                    "S": jnp.zeros((batch, H, s.ssm.head_dim, s.ssm.head_dim), jnp.float32),
                    "shift": jnp.zeros((batch, 1, d), dtype),
                },
                "cm": {"shift": jnp.zeros((batch, 1, d), dtype)},
            }
        if s.kind == "hymba":
            H = d // s.ssm.head_dim
            return {
                "attn": init_attn_cache(batch, s.attn, max_seq, dtype),
                "mamba": {"S": jnp.zeros((batch, H, s.ssm.head_dim, s.ssm.state_dim), jnp.float32)},
            }
        raise ValueError(s.kind)

    cache = one()
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (s.count, *a.shape)), cache)
