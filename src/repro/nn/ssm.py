"""Attention-free sequence mixers: RWKV-6 (Finch) and Mamba-2-style SSD heads.

Both are "diagonal decay + rank-1 update" recurrences, O(1) state in sequence
length — the property that makes rwkv6-7b / hymba-1.5b runnable at the
long_500k cell.  Training/prefill uses the *chunked parallel form* (two GEMMs
+ one masked score matmul per chunk; per-chunk cumulative decay products in
log space), which is MXU-friendly and keeps backward memory at one state per
chunk instead of one per step.  Decode applies the recurrence directly to the
carried state.

Numerics: within-chunk decay ratios ``exp(logA_t - logA_i)`` are <= 1 for the
terms that matter; the two factors are materialized separately, so per-step
log-decay is clamped to >= -8 (a decay of 3e-4/step is indistinguishable from
a reset) to keep ``exp(+|logA|)`` inside fp32 at chunk 64.  The sequential
scan oracle lives here too (``*_sequential``) and the tests assert the chunked
forms match it.

A2Q attaches to every projection in the blocks built on these mixers (r/k/v/g
/o, channel-mix, in/out projections); the recurrence itself is a
data-dependent elementwise update with no frozen weight vector, so Eq. 15 has
nothing to bound there (DESIGN.md Sec. 5, noted inapplicability).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig, SSMConfig
from repro.nn.linear import IntAct, apply_linear, chain_out_aq, init_linear
from repro.nn.module import box, normal_init

__all__ = [
    "rwkv6_chunked",
    "rwkv6_sequential",
    "ssd_chunked",
    "ssd_sequential",
    "init_rwkv6_timemix",
    "apply_rwkv6_timemix",
    "init_rwkv6_channelmix",
    "apply_rwkv6_channelmix",
    "init_mamba_heads",
    "apply_mamba_heads",
]

_MIN_LOGW = -8.0


# ---------------------------------------------------------------------------
# RWKV-6 recurrence
# ---------------------------------------------------------------------------


def rwkv6_sequential(r, k, v, w, u, S0):
    """Oracle: step-by-step scan.  Shapes (B, H, T, Dk/Dv), u (H, Dk),
    S0 (B, H, Dk, Dv).  Returns (y (B, H, T, Dv), S_T)."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,Dk) ... (B,H,Dv)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(t.swapaxes(0, 2).swapaxes(1, 2).astype(jnp.float32) for t in (r, k, v, w))
    # (T, B, H, D)
    S, ys = jax.lax.scan(step, S0.astype(jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), S


def rwkv6_chunked(r, k, v, w, u, S0, chunk: int = 32):
    """Chunked parallel form.  Same signature/semantics as the oracle."""
    B, H, T, Dk = r.shape
    Dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    f32 = jnp.float32

    def to_chunks(x):
        return x.reshape(B, H, nc, chunk, x.shape[-1]).transpose(2, 0, 1, 3, 4).astype(f32)

    logw = jnp.maximum(jnp.log(jnp.maximum(w.astype(f32), 1e-30)), _MIN_LOGW)

    def body(S, inp):
        r_c, k_c, v_c, lw = inp  # (B, H, L, D*)
        logA = jnp.cumsum(lw, axis=2)  # inclusive within-chunk products
        logA_prev = logA - lw  # exclusive
        r_in = r_c * jnp.exp(logA_prev)
        k_in = k_c * jnp.exp(-logA)
        y = jnp.einsum("bhld,bhdv->bhlv", r_in, S)  # inter-chunk
        att = jnp.einsum("bhld,bhmd->bhlm", r_in, k_in)
        tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)  # strictly lower
        y = y + jnp.einsum("bhlm,bhmv->bhlv", att * tri, v_c)
        diag = jnp.einsum("bhld,bhld->bhl", r_c, u[None, :, None, :] * k_c)
        y = y + diag[..., None] * v_c
        k_out = k_c * jnp.exp(logA[:, :, -1:, :] - logA)  # (A_L / A_i) <= 1
        S = jnp.exp(logA[:, :, -1, :])[..., None] * S + jnp.einsum(
            "bhld,bhlv->bhdv", k_out, v_c
        )
        return S, y

    xs = (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(logw))
    S, ys = jax.lax.scan(body, S0.astype(f32), xs)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, T, Dv)
    return y.astype(r.dtype), S


def rwkv6_decode_step(r, k, v, w, u, S):
    """One token: r/k/v/w (B, H, Dk|Dv), S (B, H, Dk, Dv)."""
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r, S + u[None, :, :, None] * kv)
    S = w[..., :, None] * S + kv
    return y, S


# ---------------------------------------------------------------------------
# Mamba-2-style SSD (scalar per-head decay) for hymba's mamba heads
# ---------------------------------------------------------------------------


def ssd_sequential(x, a, Bm, Cm, S0):
    """Oracle.  x (B,H,T,Dh) pre-scaled input (delta already folded in),
    a (B,H,T) per-step decay in (0,1], Bm/Cm (B,H,T,N), S0 (B,H,Dh,N).
    y_t = S_t C_t;  S_t = a_t S_{t-1} + x_t B_t^T."""

    def step(S, inp):
        x_t, a_t, b_t, c_t = inp
        S = a_t[..., None, None] * S + x_t[..., :, None] * b_t[..., None, :]
        y = jnp.einsum("bhdn,bhn->bhd", S, c_t)
        return S, y

    xs = (
        x.swapaxes(0, 2).swapaxes(1, 2).astype(jnp.float32),
        a.swapaxes(0, 2).swapaxes(1, 2).astype(jnp.float32),
        Bm.swapaxes(0, 2).swapaxes(1, 2).astype(jnp.float32),
        Cm.swapaxes(0, 2).swapaxes(1, 2).astype(jnp.float32),
    )
    S, ys = jax.lax.scan(step, S0.astype(jnp.float32), xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype), S


def ssd_chunked(x, a, Bm, Cm, S0, chunk: int = 32):
    """Chunked parallel SSD (Mamba-2): scalar decay factorizes the intra-chunk
    term into ``(C B^T) * decay-matrix`` — two GEMMs + one masked matmul."""
    B, H, T, Dh = x.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    f32 = jnp.float32

    def to_chunks(t):
        tail = t.shape[3:]
        return t.reshape(B, H, nc, chunk, *tail).transpose(2, 0, 1, 3, *range(4, 4 + len(tail))).astype(f32)

    loga = jnp.maximum(jnp.log(jnp.maximum(a.astype(f32), 1e-30)), _MIN_LOGW)

    def body(S, inp):
        x_c, la, b_c, c_c = inp  # (B,H,L,Dh), (B,H,L), (B,H,L,N), (B,H,L,N)
        logA = jnp.cumsum(la, axis=2)  # inclusive
        # y_t = C_t S_t;  S_t includes the i == t update -> inclusive ratios.
        c_in = c_c * jnp.exp(logA)[..., None]
        b_in = b_c * jnp.exp(-logA)[..., None]
        y = jnp.einsum("bhln,bhdn->bhld", c_in, S)  # inter-chunk
        att = jnp.einsum("bhln,bhmn->bhlm", c_in, b_in)
        tri = jnp.tril(jnp.ones((chunk, chunk), f32))  # includes diagonal
        y = y + jnp.einsum("bhlm,bhmd->bhld", att * tri, x_c)
        b_out = b_c * jnp.exp(logA[:, :, -1:] - logA)[..., None]
        S = jnp.exp(logA[:, :, -1])[..., None, None] * S + jnp.einsum(
            "bhld,bhln->bhdn", x_c, b_out
        )
        return S, y

    xs = (to_chunks(x), to_chunks(loga), to_chunks(Bm), to_chunks(Cm))
    S, ys = jax.lax.scan(body, S0.astype(f32), xs)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, T, Dh)
    return y.astype(x.dtype), S


def ssd_decode_step(x, a, Bm, Cm, S):
    f32 = jnp.float32
    x, a, Bm, Cm = (t.astype(f32) for t in (x, a, Bm, Cm))
    S = a[..., None, None] * S + x[..., :, None] * Bm[..., None, :]
    y = jnp.einsum("bhdn,bhn->bhd", S, Cm)
    return y, S


# ---------------------------------------------------------------------------
# RWKV-6 block sublayers (time-mix + channel-mix)
# ---------------------------------------------------------------------------


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x_{t-1} stream: returns (shifted x, new carry = x_T)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    shifted = jnp.concatenate([last, x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def init_rwkv6_timemix(key, d_model: int, ssm: SSMConfig, q: QuantConfig) -> dict:
    H = d_model // ssm.head_dim
    Dk = ssm.head_dim
    ks = jax.random.split(key, 8)
    lin = functools.partial(init_linear, cfg=q)
    return {
        "mix": box(jnp.full((5, d_model), 0.5, jnp.float32), (None, "embed")),
        "wr": lin(ks[0], d_model, d_model, axes=("embed", "heads")),
        "wk": lin(ks[1], d_model, d_model, axes=("embed", "heads")),
        "wv": lin(ks[2], d_model, d_model, axes=("embed", "heads")),
        "wg": lin(ks[3], d_model, d_model, axes=("embed", "heads")),
        "wo": lin(ks[4], d_model, d_model, axes=("heads", "embed")),
        # data-dependent decay LoRA: d_model -> rank -> d_model
        "w_lora_a": box(normal_init(ks[5], (d_model, ssm.lora_rank), 0.02), ("embed", None)),
        "w_lora_b": box(normal_init(ks[6], (ssm.lora_rank, d_model), 0.02), (None, "heads")),
        "w0": box(jnp.zeros((d_model,), jnp.float32) - 0.6, ("heads",)),
        "u": box(normal_init(ks[7], (H, Dk), 0.02), ("heads", None)),
        "ln_scale": box(jnp.ones((d_model,), jnp.float32), ("embed",)),
    }


def apply_rwkv6_timemix(
    params: dict,
    x: jnp.ndarray,
    ssm: SSMConfig,
    q: QuantConfig,
    state: Optional[dict] = None,
    *,
    compute_dtype=jnp.bfloat16,
    int_forward: bool = False,
    int_chain: bool = False,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """state = {'S': (B,H,Dk,Dv), 'shift': (B,1,d)} for decode; None = parallel.

    With a state, ``T`` may exceed 1 (chunked prefill): the recurrence starts
    from the carried ``S`` and the updated state reflects all ``T`` steps, so
    feeding a prompt in chunks is equivalent to feeding it token by token.

    Every time-mix projection is a chain break (wr/wk/wv/wg consume distinct
    token-shift mixes of the fp input; wo sits behind the groupnorm + silu
    gate), so under ``int_chain`` each folds its act-quant into the kernel
    prologue — no int8 handoff exists inside this mixer.
    """
    B, T, D = x.shape
    Dk = ssm.head_dim
    H = D // Dk
    lin = functools.partial(
        apply_linear, cfg=q, compute_dtype=compute_dtype,
        int_forward=int_forward, int_chain=int_chain,
    )
    last = state["shift"] if state is not None else None
    xs, new_shift = _token_shift(x, last)
    mix = params["mix"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + mix[i] * (xs - x) for i in range(5))
    to_heads = lambda t: t.reshape(B, T, H, Dk).transpose(0, 2, 1, 3)
    r = to_heads(lin(params["wr"], x=xr, site="tm.wr"))
    k = to_heads(lin(params["wk"], x=xk, site="tm.wk"))
    v = to_heads(lin(params["wv"], x=xv, site="tm.wv"))
    g = lin(params["wg"], x=xg, site="tm.wg")
    lora = jnp.tanh(xw.astype(jnp.float32) @ params["w_lora_a"].astype(jnp.float32))
    dd = lora @ params["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(params["w0"].astype(jnp.float32) + dd))  # (B,T,D) in (0,1)
    w = to_heads(w)
    u = params["u"].astype(jnp.float32)

    if state is None:
        S0 = jnp.zeros((B, H, Dk, Dk), jnp.float32)
        y, S = rwkv6_chunked(r, k, v, w, u, S0, chunk=ssm.chunk)
        new_state = None
    elif T == 1:
        y1, S = rwkv6_decode_step(r[:, :, 0], k[:, :, 0], v[:, :, 0], w[:, :, 0], u, state["S"])
        y = y1[:, :, None, :]
        new_state = {"S": S, "shift": new_shift}
    else:
        S0 = state["S"].astype(jnp.float32)
        if T % ssm.chunk == 0:
            y, S = rwkv6_chunked(r, k, v, w, u, S0, chunk=ssm.chunk)
        else:
            y, S = rwkv6_sequential(r, k, v, w, u, S0)
        new_state = {"S": S, "shift": new_shift}
    y = y.transpose(0, 2, 1, 3).reshape(B, T, D)
    # per-head groupnorm then silu(g) gate
    yf = y.astype(jnp.float32).reshape(B, T, H, Dk)
    yf = (yf - yf.mean(-1, keepdims=True)) * (yf.var(-1, keepdims=True) + 1e-5) ** -0.5
    y = (yf.reshape(B, T, D) * params["ln_scale"].astype(jnp.float32)).astype(compute_dtype)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype)
    return lin(params["wo"], x=y, site="tm.wo"), new_state


def init_rwkv6_channelmix(key, d_model: int, d_ff: int, q: QuantConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "mix": box(jnp.full((d_model,), 0.5, jnp.float32), ("embed",)),
        "wk": init_linear(ks[0], d_model, d_ff, q, axes=("embed", "mlp")),
        "wv": init_linear(ks[1], d_ff, d_model, q, axes=("mlp", "embed"), input_signed=False),
    }


def apply_rwkv6_channelmix(
    params: dict,
    x: jnp.ndarray,
    q: QuantConfig,
    state: Optional[dict] = None,
    *,
    compute_dtype=jnp.bfloat16,
    int_forward: bool = False,
    int_chain: bool = False,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """``wk -> relu² -> wv`` is the archetypal int8 chain: under ``int_chain``
    wk squares-relus the rescaled accumulator in its own epilogue and
    requantizes straight into wv's (unsigned) quantizer — the codes cross as
    an ``IntAct`` and no fp32 activation is ever materialized between them."""
    lin = functools.partial(
        apply_linear, cfg=q, compute_dtype=compute_dtype,
        int_forward=int_forward, int_chain=int_chain,
    )
    last = state["shift"] if state is not None else None
    xs, new_shift = _token_shift(x, last)
    xk = x + params["mix"].astype(x.dtype) * (xs - x)
    out_aq = (chain_out_aq(params["wv"], q, input_signed=False, act_fn="relu2")
              if int_chain else None)
    h = lin(params["wk"], x=xk, site="cm.wk", out_aq=out_aq)
    if not isinstance(h, IntAct):
        h = jnp.square(jax.nn.relu(h))  # squared-relu: non-negative -> unsigned acts
    out = lin(params["wv"], x=h, input_signed=False, site="cm.wv")
    return out, ({"shift": new_shift} if state is not None else None)


# ---------------------------------------------------------------------------
# Mamba heads (hymba): Mamba-2 SSD with scalar per-head decay
# ---------------------------------------------------------------------------


def init_mamba_heads(key, d_model: int, ssm: SSMConfig, q: QuantConfig) -> dict:
    H = d_model // ssm.head_dim
    N = ssm.state_dim
    ks = jax.random.split(key, 5)
    return {
        "in_proj": init_linear(ks[0], d_model, 2 * d_model, q, axes=("embed", "heads")),
        "bc_proj": init_linear(ks[1], d_model, 2 * H * N, q, axes=("embed", "heads")),
        "dt_proj": init_linear(ks[2], d_model, H, q, axes=("embed", "heads")),
        "A_log": box(jnp.zeros((H,), jnp.float32), ("heads",)),
        "D": box(jnp.ones((H, ssm.head_dim), jnp.float32), ("heads", None)),
        "out_proj": init_linear(ks[3], d_model, d_model, q, axes=("heads", "embed")),
        "dt_bias": box(jnp.full((H,), -4.6, jnp.float32), ("heads",)),  # softplus ~ 0.01
    }


def apply_mamba_heads(
    params: dict,
    x: jnp.ndarray,
    ssm: SSMConfig,
    q: QuantConfig,
    state: Optional[dict] = None,
    *,
    compute_dtype=jnp.bfloat16,
    int_forward: bool = False,
    int_chain: bool = False,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """state = {'S': (B,H,Dh,N)} for decode.  All four projections are chain
    breaks (the SSD core and the silu gate need fp values), so ``int_chain``
    folds each act-quant into the kernel prologue only."""
    B, T, D = x.shape
    Dh = ssm.head_dim
    H = D // Dh
    N = ssm.state_dim
    lin = functools.partial(
        apply_linear, cfg=q, compute_dtype=compute_dtype,
        int_forward=int_forward, int_chain=int_chain,
    )
    xz = lin(params["in_proj"], x=x, site="mamba.in_proj")
    xin, z = xz[..., :D], xz[..., D:]
    bc = lin(params["bc_proj"], x=x, site="mamba.bc_proj").astype(jnp.float32).reshape(B, T, H, 2 * N)
    Bm, Cm = bc[..., :N].transpose(0, 2, 1, 3), bc[..., N:].transpose(0, 2, 1, 3)
    dt = jax.nn.softplus(
        lin(params["dt_proj"], x=x, site="mamba.dt_proj").astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )  # (B,T,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,) negative
    a = jnp.exp(dt * A[None, None, :]).transpose(0, 2, 1)  # (B,H,T) decay in (0,1)
    xh = xin.astype(jnp.float32).reshape(B, T, H, Dh).transpose(0, 2, 1, 3)
    xh = xh * dt.transpose(0, 2, 1)[..., None]  # fold delta into the input

    if state is None:
        S0 = jnp.zeros((B, H, Dh, N), jnp.float32)
        y, S = ssd_chunked(xh, a, Bm, Cm, S0, chunk=ssm.chunk)
        new_state = None
    elif T == 1:
        y1, S = ssd_decode_step(xh[:, :, 0], a[:, :, 0], Bm[:, :, 0], Cm[:, :, 0], state["S"])
        y = y1[:, :, None, :]
        new_state = {"S": S}
    else:
        S0 = state["S"].astype(jnp.float32)
        if T % ssm.chunk == 0:
            y, S = ssd_chunked(xh, a, Bm, Cm, S0, chunk=ssm.chunk)
        else:
            y, S = ssd_sequential(xh, a, Bm, Cm, S0)
        new_state = {"S": S}
    skip = params["D"].astype(jnp.float32)[None, :, None, :] * xh
    y = (y + skip).transpose(0, 2, 1, 3).reshape(B, T, D).astype(compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype)
    return lin(params["out_proj"], x=y, site="mamba.out_proj"), new_state
