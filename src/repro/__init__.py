"""A2Q reproduction package root.

Importing ``repro`` applies the jax compatibility shims in
:mod:`repro._compat` (notably ``jax.shard_map`` on older jax releases) so
every entrypoint — tests, launchers, subprocess bodies — sees one API surface.
"""

from repro import _compat  # noqa: F401
